"""Sharded multi-device instances: TP/EP correctness, shard-aware
handoff, width-aware cost model / controller / placement identity.

Single-device cases always run.  Multi-device cases need >= 2 XLA
devices — the CI ``shard-tests`` job provides them with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; under the
default one-device tier-1 run they skip.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.costmodel import A100, BatchCostModel
from repro.engine import BatchItem, InstanceEngine
from repro.models.model import init_params

MOE = "qwen3-moe-30b-a3b"
DENSE = "qwen2.5-14b"

multi = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 XLA devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _greedy(eng, slot, prompt, n):
    out = eng.run_batch([BatchItem(slot, prompt, 0, want_logits=True)])
    first_logits = np.asarray(out[slot])
    toks = [int(first_logits.argmax())]
    pos = len(prompt)
    for _ in range(n - 1):
        out = eng.run_batch([BatchItem(slot, np.array([toks[-1]], np.int32),
                                       pos, want_logits=True)])
        toks.append(int(out[slot].argmax()))
        pos += 1
    return toks, first_logits


# ---------------------------------------------------------------------------
# sharded execution correctness (multi-device)
# ---------------------------------------------------------------------------
@multi
@pytest.mark.parametrize("name", [MOE, DENSE])
def test_tp_logits_match_single_device(name):
    """A TP=2 (EP=2 on the MoE arch) instance must produce the same
    logits and greedy tokens as the unsharded reference."""
    cfg = get_smoke_config(name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, 24).astype(np.int32)

    ref = InstanceEngine(cfg, params, n_slots=2, max_len=96)
    ref_toks, ref_logits = _greedy(ref, ref.alloc("r"), prompt, 6)

    tp = InstanceEngine(cfg, params, n_slots=2, max_len=96,
                        devices=jax.devices()[:2])
    assert tp.tp == 2
    toks, logits = _greedy(tp, tp.alloc("r"), prompt, 6)
    np.testing.assert_allclose(logits, ref_logits, atol=2e-4, rtol=2e-4)
    assert toks == ref_toks


@multi
@pytest.mark.parametrize("src_tp,dst_tp", [(2, 1), (1, 2)])
def test_handoff_across_shard_widths(src_tp, dst_tp):
    """export_state gathers shards into the portable piece format, so a
    handoff crosses widths (TP=2 -> TP=1 and back) without drift."""
    cfg = get_smoke_config(MOE)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab_size, 24).astype(np.int32)

    ref = InstanceEngine(cfg, params, n_slots=2, max_len=96)
    ref_toks, _ = _greedy(ref, ref.alloc("r"), prompt, 7)

    def make(tp):
        devs = jax.devices()[:tp] if tp > 1 else None
        return InstanceEngine(cfg, params, n_slots=2, max_len=96,
                              devices=devs)

    A, B = make(src_tp), make(dst_tp)
    sa = A.alloc("r")
    A.run_batch([BatchItem(sa, prompt[:16], 0)])
    pieces = A.export_state(sa, upto=16, chunk=8)
    sb = B.alloc("r")
    B.import_state(sb, pieces)
    out = B.run_batch([BatchItem(sb, prompt[16:], 16, want_logits=True)])
    toks = [int(out[sb].argmax())]
    pos = len(prompt)
    for _ in range(6):
        out = B.run_batch([BatchItem(sb, np.array([toks[-1]], np.int32),
                                     pos, want_logits=True)])
        toks.append(int(out[sb].argmax()))
        pos += 1
    assert toks == ref_toks


@multi
def test_moe_ep_routing_equivalence():
    """moe_fwd under an expert-sharded shard_map (each shard owning a
    contiguous expert slice) must reproduce the full-expert output: the
    replicated router/capacity ranking means all shards agree on the
    dispatch, and the combine psum sums each token exactly once."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map_compat
    from repro.models.layers import moe_fwd
    from repro.models.tp import tp_context

    cfg = get_smoke_config(MOE)
    E, dm, ff = cfg.moe_experts, cfg.d_model, cfg.moe_d_ff
    assert E % 2 == 0
    k = jax.random.split(jax.random.PRNGKey(3), 5)
    p = {"router": jax.random.normal(k[0], (dm, E), jnp.float32) * 0.02,
         "wi": jax.random.normal(k[1], (E, dm, ff), jnp.float32) * 0.02,
         "wg": jax.random.normal(k[2], (E, dm, ff), jnp.float32) * 0.02,
         "wo": jax.random.normal(k[3], (E, ff, dm), jnp.float32) * 0.02}
    x = jax.random.normal(k[4], (2, 8, dm), jnp.float32)

    y_ref, aux_ref = moe_fwd(p, x, cfg)

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("model",))
    p_specs = {"router": P(), "wi": P("model"), "wg": P("model"),
               "wo": P("model")}

    def body(p, x):
        with tp_context("model"):
            return moe_fwd(p, x, cfg)

    y, aux = shard_map_compat(body, mesh, (p_specs, P()), (P(), P()))(p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)


@multi
def test_engine_backend_sharded_session_end_to_end():
    """A qwen3-MoE-shaped pool of TP=2/EP=2 instances serves a small
    trace end-to-end through the full session stack, and the backend
    reports the shard width via describe()/gauges()."""
    from repro.core.request import Request
    from repro.core.session import ServeSession, SessionConfig
    from repro.engine.backend import EngineBackend
    from repro.sim.policies import DynaServePolicy

    cfg = get_smoke_config(MOE)
    params = init_params(cfg, jax.random.PRNGKey(0))
    backend = EngineBackend(cfg, params, n_slots=8, max_len=96,
                            devices_per_instance=2)
    rng = np.random.default_rng(0)
    reqs, t = [], 0.0
    for i in range(4):
        t += rng.exponential(0.05)
        reqs.append(Request(f"r{i}", t, int(rng.integers(8, 24)), 6,
                            predicted_decode=6))
    policy = DynaServePolicy(backend.cost, 0.1)
    session = ServeSession(backend, policy,
                           SessionConfig(n_instances=2, slo=0.1))
    m = session.run(reqs)
    assert m.completed == m.offered == 4
    assert backend.describe()["devices_per_instance"] == 2
    for iid, eng in backend.engines.items():
        assert eng.tp == 2
        assert backend.gauges(iid)["devices"] == 2.0


# ---------------------------------------------------------------------------
# validation / cost model / controller / placement (single-device)
# ---------------------------------------------------------------------------
def test_validate_tp_rejections():
    dev = jax.devices()[0]
    cfg = get_smoke_config(DENSE)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # n_heads=8 but n_kv_heads=2: 3 divides neither
    with pytest.raises(ValueError, match="% 3 != 0"):
        InstanceEngine(cfg, params, devices=[dev] * 3)
    # quantized pages have no shardable scale planes
    with pytest.raises(ValueError, match="quantized|fp8"):
        InstanceEngine(cfg, params, devices=[dev] * 2, kv_precision="fp8")
    # GQA cap: kv_heads=2 forbids TP=4 even though n_heads=8 divides
    with pytest.raises(ValueError, match="n_kv_heads"):
        InstanceEngine(cfg, params, devices=[dev] * 4)


def test_achieved_parallelism_records_replication():
    import warnings as _w
    from repro.utils.sharding import achieved_parallelism, _warned
    cfg = get_smoke_config(DENSE)          # heads=8, kv_heads=2
    _warned.clear()
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        ap = achieved_parallelism(cfg, 4)
        assert ap.heads == 4 and ap.kv_heads == 1    # kv replicated
        assert any("n_kv_heads" in str(w.message) for w in rec)
    with _w.catch_warnings(record=True) as rec:      # one-time only
        _w.simplefilter("always")
        achieved_parallelism(cfg, 4)
        assert not rec


def test_cost_model_tp_pricing():
    from repro.configs import get_config
    cfg = get_config(DENSE)       # full-size: compute dominates overhead
    base = BatchCostModel(cfg, A100)
    tp1 = BatchCostModel(cfg, A100, tp_degree=1)
    tp2 = BatchCostModel(cfg, A100, tp_degree=2)
    probes = [(256, 0, 0, 0), (128, 64, 4, 96), (0, 0, 8, 128)]
    for M, ctx, dnum, dctx in probes:
        a = base.mixed_batch_latency(M, ctx, dnum, dctx)
        # tp_degree=1 is byte-exact legacy behaviour
        assert tp1.mixed_batch_latency(M, ctx, dnum, dctx) == a
        b = tp2.mixed_batch_latency(M, ctx, dnum, dctx)
        # faster than 1-device, slower than the ideal 2x (collectives
        # and unsharded work keep it sub-linear)
        assert b < a
        assert b > a / 2
    # GQA cap: width 5 divides n_heads=40 but not n_kv_heads=8, so the
    # KV cache is replicated (no KV-read speedup) while attention FLOPs
    # still shard
    tp5 = BatchCostModel(cfg, A100, tp_degree=5)
    assert tp5.kv_tp == 1 and tp5.attn_tp == 5
    assert tp5.coll_s_per_tok > tp2.coll_s_per_tok > 0.0


def test_pool_controller_width_trades():
    from repro.core.elastic import (ElasticConfig, InstanceStat,
                                    MergeInstances, PoolController,
                                    SplitInstance)
    cfg = ElasticConfig(min_instances=1, max_instances=2,
                        max_devices_per_instance=2, widen_cooldown=0.0,
                        load_ewma_alpha=1.0)
    pc = PoolController(cfg)
    loaded = [InstanceStat(iid=i, drain_time=5.0,
                           queued_prefill_tokens=4000,
                           queued_decode_tokens=400, n_queued=10,
                           draining=False, role_bias=0.0, devices=1)
              for i in range(2)]
    acts = pc.decide(loaded, now=10.0)
    merges = [a for a in acts if isinstance(a, MergeInstances)]
    assert len(merges) == 1
    assert sorted(merges[0].donors) == [0, 1] and merges[0].devices == 2

    pc2 = PoolController(cfg)
    quiet = [InstanceStat(iid=0, drain_time=0.05, queued_prefill_tokens=0,
                          queued_decode_tokens=0, n_queued=0,
                          draining=False, role_bias=0.0, devices=2)]
    acts2 = pc2.decide(quiet, now=20.0)
    splits = [a for a in acts2 if isinstance(a, SplitInstance)]
    assert len(splits) == 1
    assert splits[0].iid == 0 and splits[0].devices == 1

    # default config (max_devices_per_instance=1) never trades width
    pc3 = PoolController(ElasticConfig(max_instances=2,
                                       load_ewma_alpha=1.0))
    acts3 = pc3.decide(loaded, now=10.0)
    assert not [a for a in acts3
                if isinstance(a, (MergeInstances, SplitInstance))]


def test_elastic_sim_executes_width_trade():
    """End-to-end in the simulator: a loaded 2-member pool capped at 2
    members merges into a TP=2 instance (the width <-> count trade)."""
    from repro.configs import get_config
    from repro.core.elastic import ElasticConfig
    from repro.core.session import ServeSession, SessionConfig
    from repro.data.workloads import generate_trace
    from repro.sim.policies import ElasticDynaServePolicy
    from repro.sim.simulator import SimBackend

    cost = BatchCostModel(get_config(DENSE), A100)
    policy = ElasticDynaServePolicy(cost, 0.1, elastic=ElasticConfig(
        min_instances=1, max_instances=2, max_devices_per_instance=2,
        widen_cooldown=0.5))
    backend = SimBackend(cost, devices_per_instance=1)
    reqs = generate_trace("burstgpt", 6.0, 20.0, seed=0)
    sess = ServeSession(backend, policy,
                        SessionConfig(n_instances=2, slo=0.1))
    m = sess.run(reqs)
    assert m.completed == m.offered
    widths = {i.iid: backend.devices_for(i.iid) for i in sess.instances}
    assert max(widths.values()) == 2, widths


def test_sim_engine_placement_identity_mixed_widths():
    """Both backends build the same per-width cost models, so Algorithm
    1 makes byte-identical placement decisions over a mixed
    devices_per_instance pool."""
    from repro.core.global_scheduler import GlobalScheduler, InstanceView
    from repro.core.predictor import QueuedWork
    from repro.core.request import Request
    from repro.engine.backend import EngineBackend
    from repro.sim.simulator import SimBackend

    cfg = get_smoke_config(DENSE)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = EngineBackend(cfg, params, devices_per_instance=[1, 2])
    sim = SimBackend(BatchCostModel(cfg, A100),
                     devices_per_instance=[1, 2])

    probes = [(256, 0, 0, 0), (128, 64, 4, 96), (0, 0, 8, 128)]
    for iid in (0, 1):
        ce, cs = eng.cost_for(iid), sim.cost_for(iid)
        for M, ctx, dnum, dctx in probes:
            assert ce.mixed_batch_latency(M, ctx, dnum, dctx) == \
                cs.mixed_batch_latency(M, ctx, dnum, dctx)

    def views(backend):
        return [InstanceView(0, [QueuedWork("a", 300, 40, 0),
                                 QueuedWork("b", 100, 20, 0)],
                             cost=backend.cost_for(0)),
                InstanceView(1, [QueuedWork("c", 500, 10, 0)],
                             cost=backend.cost_for(1))]

    gs_e = GlobalScheduler(eng.cost, 0.1)
    gs_s = GlobalScheduler(sim.cost, 0.1)
    for i, (P_, D) in enumerate([(400, 60), (900, 30), (64, 128)]):
        r = Request(f"r{i}", 0.0, P_, D, predicted_decode=D)
        pe = gs_e.schedule(r, views(eng))
        ps = gs_s.schedule(r, views(sim))
        assert (pe.phi, pe.alpha_instance, pe.beta_instance, pe.probes) \
            == (ps.phi, ps.alpha_instance, ps.beta_instance, ps.probes)
        assert pe.predicted_t1 == ps.predicted_t1
        assert pe.predicted_t2 == ps.predicted_t2


def test_devices_spec_forms():
    """The per-instance width spec mirrors kv_precision: scalar, list
    (modulo), dict with default; set_devices rewrites any form."""
    from repro.sim.simulator import SimBackend
    cost = BatchCostModel(get_smoke_config(DENSE), A100)
    sim = SimBackend(cost, devices_per_instance=[1, 2])
    assert [sim.devices_for(i) for i in range(4)] == [1, 2, 1, 2]
    sim.set_devices(0, 4)
    assert sim.devices_for(0) == 4 and sim.devices_for(2) == 1
    sim2 = SimBackend(cost, devices_per_instance={"default": 2, 3: 1})
    assert sim2.devices_for(0) == 2 and sim2.devices_for(3) == 1
    assert sim2.cost_for(3) is sim2.cost_for(3)   # cached per width
    assert sim2.describe()["devices_per_instance"] == "mixed"


def test_engine_device_shortage_hint():
    """Asking for a wider instance than the host has devices raises
    with the XLA_FLAGS hint (don't spawn — fail at assignment)."""
    from repro.engine.backend import EngineBackend
    cfg = get_smoke_config(DENSE)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = jax.device_count() + 2
    backend = EngineBackend(cfg, params, devices_per_instance=n)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        backend.spawn(0)
