import os
import sys

# src layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see ONE device (the dry-run subprocess sets its own flags).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
