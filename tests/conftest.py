import os
import sys

# src layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see ONE device (the dry-run subprocess sets its own flags).
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_addoption(parser):
    parser.addoption(
        "--overlap", action="store_true", default=False,
        help="run every suite with pipelined (dispatch-ahead) execution "
             "default-on: sessions that don't pin SessionConfig.overlap "
             "use the async engine path, guarding the compat path "
             "(token streams must not change)")


def pytest_configure(config):
    if config.getoption("--overlap"):
        import repro.core.session as session
        session.DEFAULT_OVERLAP = True
