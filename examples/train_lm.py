"""Train a ~100M-parameter dense LM for a few hundred steps on the
synthetic token pipeline, with periodic checkpointing.  On CPU this is
slow but real; pass --steps 20 for a quick look.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.models.config import ModelConfig
from repro.data.tokens import token_batches
from repro.models.model import init_params
from repro.training import train_loop
from repro.training.optimizer import AdamWConfig

CFG_100M = ModelConfig(
    name="lm-100m", arch_type="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=16384,
    mlp="swiglu", norm="rmsnorm", tie_embeddings=True, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = CFG_100M
    n = cfg.param_count()
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps "
          f"@ batch={args.batch} seq={args.seq}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    res = train_loop(
        cfg, params, token_batches(cfg, args.batch, args.seq),
        AdamWConfig(lr=6e-4, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps),
        steps=args.steps, log_every=max(1, args.steps // 20),
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=max(50, args.steps // 4))
    for h in res["history"]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  {h['elapsed']:.0f}s")
    print("checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
