"""The HTTP front door, end to end in one process: boot an in-process
``ServingServer`` (sim backend, ephemeral port), talk to it with plain
``urllib`` + a raw socket for SSE, then read back the Prometheus metrics
and the request's trace spans.

  PYTHONPATH=src python examples/serve_http.py [--backend engine]

Against a standalone server (``python -m repro.launch.serve --http``)
the same requests work from curl:

  curl localhost:8000/v1/completions -d '{"prompt": "hello", "max_tokens": 8}'
  curl -N localhost:8000/v1/completions \\
       -d '{"prompt": "hello", "max_tokens": 8, "stream": true, "slo": "interactive"}'
"""
import argparse
import json
import os
import socket
import sys
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.http import ServerConfig, ServingServer


def post_json(port, path, obj):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req) as resp:
        return json.loads(resp.read()), dict(resp.headers)


def stream_sse(port, path, obj):
    """Raw-socket SSE client: yields each data event as it arrives."""
    payload = json.dumps(obj).encode()
    s = socket.create_connection(("127.0.0.1", port), timeout=30)
    s.sendall(f"POST {path} HTTP/1.1\r\nHost: localhost\r\n"
              f"Content-Type: application/json\r\n"
              f"Content-Length: {len(payload)}\r\n\r\n".encode() + payload)
    buf = b""
    while b"\r\n\r\n" not in buf:
        buf += s.recv(4096)
    _head, buf = buf.split(b"\r\n\r\n", 1)
    while True:                      # chunked body -> SSE events
        data = s.recv(4096)
        if not data:
            break
        buf += data
        while b"\n\n" in buf:
            event, _, buf = buf.partition(b"\n\n")
            for line in event.splitlines():
                if line.startswith(b"data: "):
                    yield line[6:].decode()
    s.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["sim", "engine"], default="sim")
    args = ap.parse_args()

    server = ServingServer(ServerConfig(port=0, backend=args.backend,
                                        admission=True)).start()
    port = server.port
    print(f"== in-process {args.backend} server on port {port} ==\n")

    # 1. unary completion
    out, headers = post_json(port, "/v1/completions",
                             {"prompt": "the quick brown fox",
                              "max_tokens": 8})
    print("unary completion:", out["choices"][0]["text"].strip())
    print("  usage:", out["usage"], " trace:", headers.get("x-trace-id"))

    # 2. streamed chat completion with an SLO class
    print("\nstreamed chat (interactive class): ", end="", flush=True)
    for data in stream_sse(port, "/v1/chat/completions",
                           {"messages": [{"role": "user",
                                          "content": "say something"}],
                            "max_tokens": 6, "stream": True,
                            "slo": "interactive"}):
        if data == "[DONE]":
            break
        delta = json.loads(data)["choices"][0]["delta"]
        print(delta.get("content", ""), end="", flush=True)
    print()

    # 3. metrics + trace spans
    with urllib.request.urlopen(f"http://127.0.0.1:{port}/metrics") as r:
        text = r.read().decode()
    wanted = ("dynaserve_requests_total", "dynaserve_ttft_seconds_count",
              "dynaserve_queue_depth")
    print("\nmetrics sample:")
    for line in text.splitlines():
        if line.startswith(wanted):
            print(" ", line)
    trace = server.tracer.finished[-1]
    print(f"\ntrace {trace['trace_id']} ({trace['outcome']}, "
          f"{trace['n_tokens']} tokens):")
    for span in trace["spans"]:
        print(f"  {span['name']:<10} {span['dur']*1e3:8.2f} ms")

    server.stop()
    print("\nclean shutdown")


if __name__ == "__main__":
    main()
