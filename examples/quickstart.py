"""Quickstart: the whole stack in one minute on CPU.

1. Instantiate a reduced Qwen-2.5-style model.
2. Train it for 30 steps on the synthetic pipeline.
3. Serve 4 requests through DynaServe's two-level scheduler on two real
   engine instances, with micro-request splitting + KV handoff.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.request import INTERACTIVE
from repro.data.tokens import token_batches
from repro.engine.cluster import ServingCluster
from repro.models.model import init_params
from repro.training import train_loop
from repro.training.optimizer import AdamWConfig


def main():
    cfg = get_smoke_config("qwen2.5-14b")
    print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model})")

    params = init_params(cfg, jax.random.PRNGKey(0))
    res = train_loop(cfg, params, token_batches(cfg, 8, 64),
                     AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
                     steps=30, log_every=10)
    print("train:", [f"step {h['step']}: loss {h['loss']:.3f}"
                     for h in res["history"]])
    params = res["params"]

    cluster = ServingCluster(cfg, params, n_instances=2, max_len=160)
    rng = np.random.default_rng(0)
    # streaming API: generate() returns a handle; iterating it pumps the
    # serving event loop and yields tokens as they are sampled
    handles = [cluster.session.generate(
        rng.integers(0, cfg.vocab_size, int(n)), 12, slo=INTERACTIVE)
        for n in (64, 40, 24, 48)]
    for h in handles:
        toks = list(h)
        print(f"  {h.rid}: P={h.req.P} [{h.state}] generated={toks}")
    print(f"KV handoff between instances: {cluster.kv_bytes_moved} bytes")


if __name__ == "__main__":
    main()
