"""End-to-end serving driver (the paper's kind of workload): batched
requests with skewed prefill/decode mixes served by DynaServe's full
stack — global binary-search splitting (Algorithm 1), per-instance batch
composition, real cross-instance chunked KV/state handoff — on real JAX
engines.  Also runs the same batch in colocation mode and verifies the
generations are token-identical (scheduling must never change results).

  PYTHONPATH=src python examples/serve_cluster.py [--arch mamba2-780m]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.engine.cluster import ServingCluster
from repro.models.model import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)

    # skewed mix: long-prompt/short-output + short-prompt/long-output
    specs = []
    for i in range(args.requests):
        if i % 2 == 0:
            specs.append((int(rng.integers(48, 96)), 6))    # prefill-heavy
        else:
            specs.append((int(rng.integers(8, 20)), 24))    # decode-heavy

    def serve(split):
        cluster = ServingCluster(cfg, params, n_instances=2,
                                 n_slots=args.requests + 2,
                                 max_len=192, split=split)
        t0 = time.time()
        reqs = [cluster.submit(rng_local.integers(0, cfg.vocab_size, p), d)
                for (p, d), rng_local in
                zip(specs, [np.random.default_rng(7 + i)
                            for i in range(len(specs))])]
        cluster.run_until_done(reqs)
        return reqs, time.time() - t0, cluster

    reqs_dyn, dt_dyn, cl = serve(split=True)
    reqs_col, dt_col, _ = serve(split=False)

    toks = sum(len(r.generated) for r in reqs_dyn)
    print(f"arch={cfg.name} requests={len(reqs_dyn)} output_tokens={toks}")
    print(f"DynaServe (2 unified instances): {dt_dyn:.2f}s wall "
          f"({toks/dt_dyn:.1f} tok/s CPU), KV handoff "
          f"{cl.kv_bytes_moved/1024:.1f} KiB")
    print(f"Colocation  (no splitting):      {dt_col:.2f}s wall")
    same = all(a.generated == b.generated
               for a, b in zip(reqs_dyn, reqs_col))
    print("generations identical across scheduling modes:", same)
    assert same
    for r in reqs_dyn[:4]:
        print(f"  {r.req.rid}: P={r.req.P} D={r.max_new_tokens} "
              f"-> {r.generated[:6]}...")


if __name__ == "__main__":
    main()
