"""Online serving on real JAX engines through the ``ServeSession`` API:
streaming token delivery, SLO classes, mid-flight cancellation — with a
correctness check that scheduling never changes generations (the same
batch served in colocation mode is token-identical).

Builds the session directly from its parts (``EngineBackend`` + policy),
the way new code should; the ``ServingCluster`` wrapper remains only as
a compat shim for seed-era callers.

  PYTHONPATH=src python examples/serve_cluster.py [--arch mamba2-780m]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.request import BATCH, INTERACTIVE, RequestState
from repro.core.session import ServeSession, SessionConfig
from repro.engine.backend import EngineBackend
from repro.models.model import init_params
from repro.sim.policies import ColocationPolicy, DynaServePolicy


def make_session(cfg, params, n_slots, split: bool):
    backend = EngineBackend(cfg, params, n_slots=n_slots, max_len=192)
    policy = (DynaServePolicy(backend.cost, 0.100) if split
              else ColocationPolicy(chunk=64, slo_aware=False))
    session = ServeSession(backend, policy, SessionConfig(n_instances=2))
    return session, backend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)

    # skewed mix: long-prompt/short-output + short-prompt/long-output
    specs = []
    for i in range(args.requests):
        if i % 2 == 0:
            specs.append((int(rng.integers(48, 96)), 6))    # prefill-heavy
        else:
            specs.append((int(rng.integers(8, 20)), 24))    # decode-heavy
    prompts = [np.random.default_rng(7 + i).integers(0, cfg.vocab_size, p)
               for i, (p, _) in enumerate(specs)]

    def serve(split):
        session, backend = make_session(cfg, params, 2 * args.requests,
                                        split)
        t0 = time.time()
        handles = [session.generate(
            prompts[i], d, rid=f"req{i}",
            slo=INTERACTIVE if i % 2 else BATCH)
            for i, (_, d) in enumerate(specs)]
        outs = [list(h) for h in handles]       # stream every request
        return handles, outs, time.time() - t0, backend

    hs_dyn, outs_dyn, dt_dyn, be = serve(split=True)
    hs_col, outs_col, dt_col, _ = serve(split=False)

    toks = sum(len(t) for t in outs_dyn)
    print(f"arch={cfg.name} requests={len(hs_dyn)} output_tokens={toks}")
    print(f"DynaServe (2 unified instances): {dt_dyn:.2f}s wall "
          f"({toks/dt_dyn:.1f} tok/s CPU), KV handoff "
          f"{be.kv_bytes_moved/1024:.1f} KiB")
    print(f"Colocation  (no splitting):      {dt_col:.2f}s wall")
    same = all(a == b for a, b in zip(outs_dyn, outs_col))
    print("generations identical across scheduling modes:", same)
    assert same
    for h, toks_h in list(zip(hs_dyn, outs_dyn))[:4]:
        print(f"  {h.rid}: P={h.req.P} slo={h.req.slo.name} "
              f"-> {toks_h[:6]}...")

    # mid-flight cancellation frees slots and aborts pending handoffs
    session, _ = make_session(cfg, params, 8, split=True)
    h = session.generate(prompts[0], 24, rid="cancelme")
    for i, _tok in enumerate(h):
        if i == 2:
            h.cancel()
    print(f"cancelled after {len(h.tokens)} tokens: state={h.state}")
    assert h.state == RequestState.CANCELLED


if __name__ == "__main__":
    main()
