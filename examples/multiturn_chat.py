"""Multi-turn chat on real engines with the shared-prefix KV cache.

Drives ``session.generate`` over one conversation the way a chat client
does: each turn's prompt is the full history — previous prompts, the
model's actual sampled replies, and a new user message.  With
``prefix_cache=True`` the engine recognizes the re-sent history, splices
its cached pages, and prefills only the new tokens; the per-turn stats
show the saved prefill growing with the conversation.

  PYTHONPATH=src python examples/multiturn_chat.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                  # noqa: E402
import numpy as np                                          # noqa: E402

from repro.configs import get_smoke_config                  # noqa: E402
from repro.core.session import ServeSession, SessionConfig  # noqa: E402
from repro.engine.backend import EngineBackend              # noqa: E402
from repro.models.model import init_params                  # noqa: E402
from repro.sim.policies import DynaServePolicy              # noqa: E402

TURNS = 4
USER_TOKENS = 24            # synthetic "user message" length
REPLY_TOKENS = 16


def main() -> None:
    cfg = get_smoke_config("qwen2.5-14b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    backend = EngineBackend(cfg, params, n_slots=8, max_len=512,
                            page_size=8, prefix_cache=True)
    session = ServeSession(backend, DynaServePolicy(backend.cost),
                           SessionConfig(n_instances=2))

    rng = np.random.default_rng(0)
    history = rng.integers(0, cfg.vocab_size, USER_TOKENS).astype(np.int32)
    saved_before = 0
    for turn in range(TURNS):
        handle = session.generate(history, REPLY_TOKENS,
                                  rid=f"turn{turn}")
        reply = np.asarray(handle.result(), np.int32)
        saved = session.prefix_saved_tokens - saved_before
        saved_before = session.prefix_saved_tokens
        print(f"turn {turn}: prompt={len(history)} tok, "
              f"reply={len(reply)} tok, prefill skipped via cache="
              f"{saved} tok")
        # the client folds the model's reply + a new user message into
        # the next prompt — exactly the prefix the cache will hit
        user = rng.integers(0, cfg.vocab_size, USER_TOKENS).astype(np.int32)
        history = np.concatenate([history, reply, user])

    m = session.metrics()
    print(f"\nconversation done: hit_rate={m.prefix_hit_rate:.2f} "
          f"({m.prefix_hits}/{m.prefix_lookups} lookups), "
          f"saved_prefill={m.prefix_saved_tokens} tok, "
          f"saved_handoff={m.prefix_handoff_saved_tokens} tok, "
          f"computed_prefill={m.prefill_tokens_computed} tok")


if __name__ == "__main__":
    main()
