"""Mini reproduction of the paper's Figure 8 comparison: goodput of
DynaServe vs PD-colocation vs PD-disaggregation on two A100-modelled
instances under the four workload shapes (calibrated simulator).

  PYTHONPATH=src python examples/paper_fig8_mini.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.costmodel import A100, BatchCostModel
from repro.data import generate_trace
from repro.sim import (ClusterSim, ColocationPolicy, DisaggregationPolicy,
                       DynaServePolicy, SimConfig)


def main():
    cost = BatchCostModel(get_config("qwen2.5-14b"), A100)
    print(f"{'workload':20s} {'qps':>4s} | {'coloc':>8s} {'disagg':>8s} "
          f"{'DynaServe':>9s} | best")
    for w, qps in [("burstgpt", 6), ("azure_code", 2),
                   ("arxiv_summarization", 2), ("mini_reasoning", 3)]:
        reqs = generate_trace(w, qps, 40, seed=1)
        row = {}
        for name, pol in [("coloc", ColocationPolicy(2048)),
                          ("disagg", DisaggregationPolicy()),
                          ("dyna", DynaServePolicy(cost))]:
            sim = ClusterSim(cost, pol, SimConfig(n_instances=2))
            row[name] = sim.run(reqs).goodput
        best = max(row, key=row.get)
        print(f"{w:20s} {qps:4.0f} | {row['coloc']:8.1f} {row['disagg']:8.1f} "
              f"{row['dyna']:9.1f} | {best}")


if __name__ == "__main__":
    main()
